"""Docs gates, runnable locally: every intra-repo markdown link resolves,
the required docs tree exists and is linked from README, and EXPERIMENTS.md
matches its generator (the same checks the CI docs job runs)."""
import os
import subprocess
import sys

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def _run(script, *args):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    return subprocess.run(
        [sys.executable, os.path.join(REPO, "scripts", script), *args],
        capture_output=True, text=True, env=env, cwd=REPO, timeout=300)


def test_docs_tree_exists_and_linked_from_readme():
    for rel in ("docs/ARCHITECTURE.md", "docs/TUNING.md", "EXPERIMENTS.md"):
        assert os.path.exists(os.path.join(REPO, rel)), rel
    readme = open(os.path.join(REPO, "README.md")).read()
    assert "docs/ARCHITECTURE.md" in readme
    assert "docs/TUNING.md" in readme


def test_no_broken_intra_repo_markdown_links():
    proc = _run("check_docs.py")
    assert proc.returncode == 0, proc.stdout + proc.stderr


def test_check_docs_catches_broken_link(tmp_path):
    """The gate must actually fail on a dangling link, not just pass."""
    bad = REPO + "/docs/_tmp_broken_link_test.md"
    with open(bad, "w") as f:
        f.write("[dangling](does-not-exist-anywhere.md)\n")
    try:
        proc = _run("check_docs.py", "docs/_tmp_broken_link_test.md")
        assert proc.returncode == 1, proc.stdout
        assert "BROKEN" in proc.stdout
    finally:
        os.remove(bad)


def test_experiments_md_matches_generator():
    proc = _run("make_experiments_md.py", "--check")
    assert proc.returncode == 0, proc.stdout + proc.stderr
