"""Profiling layer: strict no-op when off, real captures parse into the
per-op-family breakdown, the classifier/summarizer handle synthetic events,
PROFILE schema validation, and the engine's one-device_get-per-wave
invariant with tracing annotations enabled."""
import json
import os

import jax
import jax.numpy as jnp
import pytest

from repro.configs.catalog import ARCHITECTURES
from repro.models import build_model
from repro.profiling import (FAMILIES, PROFILE_SCHEMA_VERSION, annotate,
                             build_profile, classify_event_name,
                             load_trace_events, summarize_events, trace,
                             validate_profile)
from repro.serve import Engine, ServeConfig


# ---------------------------------------------------------------------------
# trace(...) capture
# ---------------------------------------------------------------------------

def test_trace_disabled_is_strict_noop(tmp_path):
    """Off = OFF: no directory creation, no env mutation, inert session.
    This is what lets the launchers keep trace(...) permanently in the
    serve/train hot paths."""
    target = tmp_path / "never-created"
    env_before = dict(os.environ)
    with trace(str(target), enabled=False) as s:
        jnp.square(jnp.arange(4.0)).block_until_ready()
    assert not s.enabled and s.dir is None
    assert s.trace_files() == [] and s.events() == []
    assert not target.exists()
    # falsy dir disables too, even with enabled=True
    with trace(None) as s:
        pass
    assert not s.enabled
    assert dict(os.environ) == env_before   # XLA_FLAGS & friends untouched


def test_trace_captures_parseable_breakdown(tmp_path):
    """A real (tiny) capture round-trips: gzipped Chrome-trace files appear
    under the session dir, parse with the stdlib loader, and roll up into a
    schema-valid PROFILE blob with the annotated span present."""
    target = tmp_path / "cap"
    x = jnp.ones((64, 64), jnp.float32)
    f = jax.jit(lambda a: a @ a)
    f(x).block_until_ready()               # compile outside the trace
    with trace(str(target)) as s:
        with annotate("serve.unit_test_span"):
            jax.device_get(f(x))
    assert s.enabled and s.trace_files(), "no capture written"
    events = load_trace_events(str(target))
    assert events
    blob = build_profile("serving", events=events, hardware="cpu-interpret")
    validate_profile(blob)                 # raises on any schema violation
    assert blob["schema_version"] == PROFILE_SCHEMA_VERSION
    assert set(blob["families"]) == set(FAMILIES)
    assert blob["totals"]["op_us"] > 0
    assert "serve.unit_test_span" in blob["annotations"]
    # the blob is JSON-serializable as written by scripts/profile.py
    json.dumps(blob)


def test_load_trace_events_raises_on_empty_dir(tmp_path):
    """CI's "the profiler actually ran" check: an empty trace dir is an
    error, not an empty (and trivially green) breakdown."""
    with pytest.raises(FileNotFoundError):
        load_trace_events(str(tmp_path))


# ---------------------------------------------------------------------------
# classifier + summarizer on synthetic events
# ---------------------------------------------------------------------------

def test_classify_event_name_families():
    assert classify_event_name("all-reduce.7") == "collective"
    assert classify_event_name("all-gather-start.2") == "collective"
    assert classify_event_name("reduce-scatter") == "collective"
    assert classify_event_name("dot.30") == "gemm"
    assert classify_event_name("convolution.1") == "gemm"
    assert classify_event_name("softmax_fusion") == "attention"
    assert classify_event_name("fusion.12") == "other"
    assert classify_event_name("dynamic-update-slice.4") == "other"


def _ev(name, dur, ts=0, hlo=None):
    ev = {"ph": "X", "name": name, "dur": dur, "ts": ts}
    if hlo:
        ev["args"] = {"hlo_op": hlo}
    return ev


def test_summarize_events_synthetic():
    events = [
        _ev("xla-op", 100.0, ts=0, hlo="all-reduce.1"),
        _ev("xla-op", 50.0, ts=100, hlo="all-reduce.2"),
        _ev("xla-op", 30.0, ts=150, hlo="dot.5"),
        # container op: covers the leaves above, must NOT double-count
        _ev("xla-op", 500.0, ts=0, hlo="while.3"),
        # host fetch: runtime event, no hlo_op
        _ev("np.asarray(jax.Array)", 20.0, ts=200),
        # annotate(...) marker
        _ev("serve.decode_wave", 400.0, ts=0),
        # non-duration events are ignored
        {"ph": "M", "name": "process_name"},
    ]
    s = summarize_events(events)
    assert s["families"]["collective"]["us"] == 150.0
    assert s["families"]["collective"]["count"] == 2
    assert s["families"]["gemm"]["us"] == 30.0
    assert s["families"]["host_transfer"]["us"] == 20.0
    assert s["host_syncs"] == 1
    assert s["families"]["other"]["us"] == 0.0    # while.3 excluded
    assert s["totals"]["op_us"] == 180.0          # device ops, no transfers
    # SSA numbering folds: two all-reduce events -> one top op
    assert s["top_ops"][0] == {"name": "all-reduce", "us": 150.0, "count": 2}
    assert s["annotations"] == {
        "serve.decode_wave": {"us": 400.0, "count": 1}}
    assert s["totals"]["wall_us"] == 500.0        # ts 0 .. 100+400


def test_summarize_fractions_sum_to_one():
    events = [_ev("x", 75.0, hlo="dot.1"), _ev("x", 25.0, hlo="add.2")]
    s = summarize_events(events)
    assert sum(e["fraction"] for e in s["families"].values()) == pytest.approx(1.0)
    assert s["families"]["gemm"]["fraction"] == pytest.approx(0.75)


# ---------------------------------------------------------------------------
# PROFILE schema validation
# ---------------------------------------------------------------------------

def _valid_blob():
    return build_profile("serving", events=[
        _ev("x", 10.0, hlo="dot.1"), _ev("np.asarray(jax.Array)", 1.0)])


def test_validate_profile_accepts_and_returns_blob():
    blob = _valid_blob()
    assert validate_profile(blob) is blob


def test_validate_profile_lists_every_violation():
    blob = _valid_blob()
    blob["schema_version"] = 99
    del blob["families"]["gemm"]
    blob["host_syncs"] = -1
    with pytest.raises(ValueError) as e:
        validate_profile(blob)
    msg = str(e.value)
    assert "schema_version" in msg
    assert "families['gemm'] missing" in msg
    assert "host_syncs" in msg


def test_validate_profile_rejects_empty_capture():
    """A trace that captured nothing (zero totals) must fail — that is the
    CI profiling leg's guard against a silently-dead profiler."""
    blob = build_profile("serving", events=[])
    with pytest.raises(ValueError) as e:
        validate_profile(blob)
    assert "op_us" in str(e.value) and "wall_us" in str(e.value)


# ---------------------------------------------------------------------------
# engine invariant under tracing
# ---------------------------------------------------------------------------

def test_fused_decode_one_device_get_per_wave_under_tracing(
        tmp_path, monkeypatch):
    """The annotate(...) markers in the decode path must not change the
    execution model: with a trace ACTIVE, the fused loop still performs
    exactly one jax.device_get per wave, and the capture shows the
    serve.prefill_wave/serve.decode_wave spans per wave."""
    cfg = ARCHITECTURES["llama3.2-1b"].reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    # wave pinned: the wave-specific annotation names and the one-get-per-
    # wave contract are what this test is about; the continuous scheduler's
    # one-get-per-chunk contract lives in test_recompile_count.py
    eng = Engine(model, params, ServeConfig(max_batch=2, max_len=64,
                                            scheduler="wave"))
    prompts = [[5, 9, 2], [1, 3, 3], [2, 4, 6]]      # 3 prompts, 2 slots
    eng.generate(prompts, 4)                          # compile outside count
    calls = []
    real = jax.device_get
    monkeypatch.setattr(jax, "device_get", lambda *a, **k: (
        calls.append(1), real(*a, **k))[1])
    waves0 = eng.stats()["waves"]
    with trace(str(tmp_path / "cap")) as s:
        eng.generate(prompts, 4)
    waves = eng.stats()["waves"] - waves0
    assert waves == 2
    assert len(calls) == waves                        # one fetch per wave
    ann = summarize_events(s.events())["annotations"]
    assert ann.get("serve.prefill_wave", {}).get("count") == waves
    assert ann.get("serve.decode_wave", {}).get("count") == waves
