"""Consolidated launcher CLI: one declaration of the shared flags, a
serving argument group, and warn-and-forward semantics for retired flags.

Host-side argparse only — no engine or device work.  Guards the contract
that ``launch/serve.py`` and ``launch/train.py`` expose identical common
flags (so the copies can never drift again) and that old command lines
keep working one release while printing their migration path.
"""
import argparse

import pytest

from repro.launch.common import (add_common_args, add_serving_args,
                                 deprecated_flag, forward_deprecated)

COMMON_FLAGS = ["--hardware", "--mesh", "--stats", "--tuned-dir",
                "--trace-dir"]
SERVING_FLAGS = ["--scheduler", "--page-size", "--capacity-tokens",
                 "--decode-chunk", "--no-prefix-cache"]


def _option_strings(ap):
    return {s for a in ap._actions for s in a.option_strings}


def test_common_args_single_declaration():
    ap = argparse.ArgumentParser()
    add_common_args(ap)
    assert set(COMMON_FLAGS) <= _option_strings(ap)
    args = ap.parse_args(["--mesh", "data=2,model=2", "--stats"])
    assert args.mesh == "data=2,model=2" and args.stats is True
    assert args.hardware is None and args.tuned_dir is None


def test_serving_args_group_and_defaults():
    ap = argparse.ArgumentParser()
    add_serving_args(ap)
    assert set(SERVING_FLAGS) <= _option_strings(ap)
    assert any(g.title == "serving" for g in ap._action_groups)
    args = ap.parse_args([])
    assert args.scheduler == "continuous" and args.decode_chunk == 8
    assert args.page_size is None and not args.no_prefix_cache
    with pytest.raises(SystemExit):
        ap.parse_args(["--scheduler", "bogus"])


def test_both_launchers_expose_the_same_common_flags():
    """The drift this module exists to prevent: serve.py and train.py must
    agree flag-for-flag on the shared surface."""
    from repro.launch import serve, train
    surfaces = []
    for mod in (serve, train):
        ap = argparse.ArgumentParser()
        add_common_args(ap)
        surfaces.append(_option_strings(ap) & set(COMMON_FLAGS))
        # and the modules import the shared declaration, not a copy
        assert mod.add_common_args is add_common_args
    assert surfaces[0] == surfaces[1] == set(COMMON_FLAGS)


def test_deprecated_flag_warns_and_forwards():
    ap = argparse.ArgumentParser()
    add_common_args(ap)
    deprecated_flag(ap, "--mesh-data", "--mesh", type=int)
    with pytest.warns(DeprecationWarning, match="--mesh-data is deprecated"):
        args = ap.parse_args(["--mesh-data", "4"])
    assert args.mesh_data == 4
    assert args._deprecated_used == {"mesh_data"}
    forward_deprecated(args, {"mesh_data": ("mesh", lambda v: f"data={v}")})
    assert args.mesh == "data=4"


def test_deprecated_flag_loses_to_the_modern_flag():
    ap = argparse.ArgumentParser()
    add_common_args(ap)
    deprecated_flag(ap, "--mesh-data", "--mesh", type=int)
    with pytest.warns(DeprecationWarning):
        args = ap.parse_args(["--mesh-data", "4", "--mesh", "data=8"])
    forward_deprecated(args, {"mesh_data": ("mesh", lambda v: f"data={v}")})
    assert args.mesh == "data=8"          # explicit modern flag wins


def test_deprecated_flag_hidden_and_inert_when_unused():
    ap = argparse.ArgumentParser()
    add_common_args(ap)
    deprecated_flag(ap, "--mesh-data", "--mesh", type=int)
    args = ap.parse_args([])              # no warning, no _deprecated_used
    assert getattr(args, "_deprecated_used", set()) == set()
    forward_deprecated(args, {"mesh_data": ("mesh", lambda v: f"data={v}")})
    assert args.mesh is None
    # retired flags stay out of --help
    assert "--mesh-data" not in ap.format_help()


def test_train_legacy_mesh_pair_builds_a_mesh_spec():
    """The real train.py composition: --mesh-data/--mesh-model warn and
    combine into one 'data=N,model=M' spec unless --mesh was given."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=1)
    add_common_args(ap)
    deprecated_flag(ap, "--mesh-data", "--mesh", type=int)
    deprecated_flag(ap, "--mesh-model", "--mesh", type=int)
    with pytest.warns(DeprecationWarning):
        args = ap.parse_args(["--mesh-data", "4", "--mesh-model", "2"])
    used = getattr(args, "_deprecated_used", set())
    assert used == {"mesh_data", "mesh_model"}
    if {"mesh_data", "mesh_model"} & used and not args.mesh:
        args.mesh = f"data={args.mesh_data or 1},model={args.mesh_model or 1}"
    assert args.mesh == "data=4,model=2"
