"""Prefix cache: trie/refcount invariants (host-side, randomized) and
engine-level parity — a warm cache must never change a single token.

The host-side suite drives :class:`repro.serve.prefix_cache.PrefixCache`
directly against a :class:`PageAllocator` with randomized insert/match/
evict/clear interleavings and checks the pin bookkeeping: every pinned
page stays live exactly while the trie references it, ``clear()`` returns
the pool to its pre-cache state, and eviction is LRU over entries +
childless chunk nodes.

The engine-level suite is the acceptance bar from the tentpole: serving
with a WARM cache (full hits, partial hits, COW tail divergence) is
token-for-token identical to a cold engine — greedy restart exactness,
page-spanning prefixes included.
"""
import random

import jax
import pytest

from repro.configs.catalog import ARCHITECTURES
from repro.models import build_model
from repro.serve import Engine, Request, ServeConfig
from repro.serve.kv_pages import PageAllocator
from repro.serve.prefix_cache import PrefixCache
from repro.testing import given, settings, strategies as st


# ---------------------------------------------------------------------------
# host-side trie/refcount properties (no engine, no device work)
# ---------------------------------------------------------------------------

def _sim_row_pages(alloc: PageAllocator, prompt_len: int, page: int):
    """What the scheduler would hand a freshly-prefilled row."""
    from repro.serve.kv_pages import pages_for
    return alloc.alloc(pages_for(prompt_len, page))


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(min_value=0, max_value=10_000))
def test_refcounts_never_leak_under_random_interleaving(seed):
    """Random insert/free/evict/clear schedules: pages pinned by the cache
    stay live while referenced, and after row-free + clear() the pool is
    exactly as empty as it started (alloc_count == free_count)."""
    rng = random.Random(seed)
    page = rng.choice([2, 4])
    alloc = PageAllocator(capacity_tokens=page * 32, page_size=page)
    cache = PrefixCache(alloc)
    live_rows = []
    for _ in range(rng.randint(5, 25)):
        op = rng.random()
        if op < 0.5 and alloc.free_pages > 8:
            plen = rng.randint(1, 3 * page)
            prompt = [rng.randint(1, 9) for _ in range(plen)]
            pages = _sim_row_pages(alloc, plen, page)
            cache.insert(prompt, pages, logits0=None, fixed=None)
            live_rows.append(pages)
        elif op < 0.7 and live_rows:
            alloc.free(live_rows.pop(rng.randrange(len(live_rows))))
        elif op < 0.85:
            cache.evict_one()
        else:
            cache.clear()
        # pinned pages are live by definition; they can never outnumber
        # the pool's live pages
        assert cache.pinned_pages <= alloc.used_pages
    for pages in live_rows:
        alloc.free(pages)
    cache.clear()
    assert alloc.used_pages == 0
    assert alloc.free_pages == alloc.usable_pages
    assert alloc.alloc_count == alloc.free_count
    assert cache.stats()["pinned_pages"] == 0


def test_insert_dedup_and_shared_refcounts():
    """Two prompts sharing a full-page prefix share ONE trie node; the
    shared page's refcount reflects both the rows and the single pin."""
    page = 4
    alloc = PageAllocator(capacity_tokens=64, page_size=page)
    cache = PrefixCache(alloc)
    shared = [1, 2, 3, 4]
    pages_a = _sim_row_pages(alloc, 6, page)
    cache.insert(shared + [5, 6], pages_a, None, None)
    assert cache.stats()["nodes"] == 1 and cache.stats()["entries"] == 1
    # row A's head page is pinned once by the trie on top of the row's ref
    assert alloc.refcount(pages_a[0]) == 2
    pages_b = _sim_row_pages(alloc, 6, page)
    cache.insert(shared + [7, 8], pages_b, None, None)
    st_ = cache.stats()
    assert st_["nodes"] == 1          # shared chunk deduped
    assert st_["entries"] == 2
    # B's head page was NOT pinned (the trie already owns A's copy)
    assert alloc.refcount(pages_b[0]) == 1
    m = cache.match(shared + [9])
    assert m is not None and not m.full
    assert m.pages == [pages_a[0]] and m.tokens == page


def test_lru_eviction_prefers_oldest_and_frees_pages():
    page = 2
    alloc = PageAllocator(capacity_tokens=32, page_size=page)
    cache = PrefixCache(alloc)
    rows = []
    for i in range(3):
        prompt = [10 + i, 20 + i, 30 + i]          # distinct 1-chunk + tail
        pages = _sim_row_pages(alloc, 3, page)
        rows.append(pages)
        cache.insert(prompt, pages, None, None)
    cache.match([10, 20, 30])                      # touch entry 0: now MRU
    for pages in rows:
        alloc.free(pages)
    used_before = alloc.used_pages
    assert cache.evict_one()                       # evicts entry 1 (oldest)
    assert cache.match([11, 21, 31]) is None or \
        not cache.match([11, 21, 31]).full
    assert cache.match([10, 20, 30]).full          # the touched one survives
    assert alloc.used_pages < used_before
    while cache.evict_one():
        pass
    assert alloc.used_pages == 0


def test_reclaim_reports_progress_only_on_eviction():
    page = 2
    alloc = PageAllocator(capacity_tokens=8, page_size=page)   # 4 pages
    cache = PrefixCache(alloc)
    assert cache.reclaim(1) is False               # empty cache: no progress
    pages = _sim_row_pages(alloc, 4, page)         # 2 pages
    cache.insert([1, 2, 3, 4], pages, None, None)
    alloc.free(pages)                              # cache holds the only refs
    grab = alloc.alloc(2)                          # pool: 2 cached + 2 row
    assert not alloc.can_alloc(2)
    assert cache.reclaim(2) is True                # evicts to make room
    assert alloc.can_alloc(2)
    alloc.free(grab)


# ---------------------------------------------------------------------------
# engine-level parity: a warm cache never changes a token
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def small_engine_pair():
    """One cached and one cache-disabled engine over identical params."""
    cfg = ARCHITECTURES["llama3.2-1b"].reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    warm = Engine(model, params, ServeConfig(max_batch=3, max_len=64,
                                             page_size=4))
    cold = Engine(model, params, ServeConfig(max_batch=3, max_len=64,
                                             page_size=4,
                                             prefix_cache=False))
    return cfg, warm, cold


def _gen(eng, prompts, n=5):
    handles = [eng.submit(Request(prompt=p, max_new_tokens=n))
               for p in prompts]
    eng.run()
    return [h.result(timeout=0).tokens for h in handles]


def test_warm_cache_parity_randomized_shared_prefixes(small_engine_pair):
    """Randomized page-spanning shared prefixes, served twice on the warm
    engine (miss pass + full/partial-hit pass): every pass matches the
    cache-disabled engine token-for-token."""
    cfg, warm, cold = small_engine_pair
    rng = random.Random(1234)
    for trial in range(3):
        plen = rng.randint(5, 11)                  # spans 1-2 pages at 4
        prefix = [rng.randrange(1, cfg.vocab_size) for _ in range(plen)]
        batch = [prefix + [rng.randrange(1, cfg.vocab_size)
                           for _ in range(rng.randint(0, 3))]
                 for _ in range(3)]
        expected = _gen(cold, batch)
        assert _gen(warm, batch) == expected, f"miss pass, trial {trial}"
        assert _gen(warm, batch) == expected, f"hit pass, trial {trial}"
        st_ = warm.stats()["prefix_cache"]
        assert st_["hits_full"] > 0                # the rerun actually hit


def test_cow_divergence_after_full_hit_is_exact(small_engine_pair):
    """A full hit COWs the tail page; a later prompt diverging INSIDE that
    page must not see the first request's decoded tokens bleed through."""
    cfg, warm, cold = small_engine_pair
    base = [3, 1, 4, 1, 5, 9]                      # page 4: tail = (5, 9)
    div = base[:5] + [7]                           # diverges inside page 2
    expected = _gen(cold, [base])
    assert _gen(warm, [base]) == expected          # insert
    assert _gen(warm, [base]) == expected          # full hit + COW tail
    # divergence: partial hit on page 1 only; tail prefills fresh
    assert _gen(warm, [div]) == _gen(cold, [div])
    # and the original entry still serves exactly the original tokens
    assert _gen(warm, [base]) == expected


def test_engine_refcounts_drain_clean(small_engine_pair):
    """After any mix of hits/misses, clearing the cache returns every page:
    the allocator's alloc/free ledgers balance (nothing leaked)."""
    _, warm, _ = small_engine_pair
    warm.clear_prefix_cache()
    st_ = warm.stats()["pages"]
    assert st_["used_pages"] == 0
    assert st_["alloc_count"] == st_["free_count"]
    assert warm.stats()["prefix_cache"]["pinned_pages"] == 0
