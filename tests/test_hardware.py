"""Hardware-profile layer: detection order, per-backend registry seeding,
unknown-hardware fallback, cross-backend DB isolation, engine provenance,
and the bench-trend gate."""
import json
import os
import subprocess
import sys
import warnings

import jax
import jax.numpy as jnp
import pytest

from repro.core import (CPU_INTERPRET, GPU_GENERIC, TPU_V5E, TuningDB,
                        TuningRecord, current_hardware, execution_context,
                        register_profile, sweep_gemm)
from repro.core import hardware as hw
from repro.core import registry as registry_mod
from repro.core.registry import OP_FLASH_ATTENTION, OP_GEMM, TileRegistry
from repro.core.tile_config import (FlashAttentionConfig, FlashTuningSpace,
                                    TileConfig, TuningSpace)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


class _FakeDev:
    def __init__(self, platform):
        self.platform = platform


# ---------------------------------------------------------------------------
# Detection order: explicit override > $REPRO_HARDWARE > jax.devices()
# ---------------------------------------------------------------------------

def test_cpu_only_devices_detect_cpu_interpret(monkeypatch):
    monkeypatch.delenv(hw.HARDWARE_ENV, raising=False)
    # genuine path on this CPU-only container...
    assert jax.default_backend() == "cpu"
    assert hw.detect_hardware() == CPU_INTERPRET.name
    # ...and via the injectable device list
    assert hw.detect_hardware([_FakeDev("cpu")]) == CPU_INTERPRET.name
    assert hw.detect_hardware([_FakeDev("cpu"), _FakeDev("gpu")]) == \
        GPU_GENERIC.name
    assert hw.detect_hardware([_FakeDev("tpu")]) == TPU_V5E.name


def test_env_pin_beats_detection(monkeypatch):
    monkeypatch.setenv(hw.HARDWARE_ENV, TPU_V5E.name)
    assert hw.detect_hardware() == TPU_V5E.name
    assert current_hardware() == TPU_V5E.name
    # aliases resolve through the env pin too
    monkeypatch.setenv(hw.HARDWARE_ENV, "host-cpu")
    assert hw.detect_hardware() == CPU_INTERPRET.name


def test_explicit_execution_context_override_wins(monkeypatch):
    monkeypatch.setenv(hw.HARDWARE_ENV, CPU_INTERPRET.name)
    with execution_context(hardware=TPU_V5E.name):
        assert current_hardware() == TPU_V5E.name
        with execution_context(hardware=GPU_GENERIC.name):
            assert current_hardware() == GPU_GENERIC.name
        assert current_hardware() == TPU_V5E.name
    assert current_hardware() == CPU_INTERPRET.name


def test_host_cpu_alias_resolves_to_cpu_interpret():
    assert hw.resolve_hardware("host-cpu") == CPU_INTERPRET.name
    assert hw.get_profile("host-cpu") is CPU_INTERPRET
    assert hw.get_hardware(CPU_INTERPRET.name) is CPU_INTERPRET
    with pytest.raises(KeyError, match="unknown hardware"):
        hw.get_profile("knights-landing")


# ---------------------------------------------------------------------------
# Registry seeding from profiles + the unknown-hardware fallback bugfix
# ---------------------------------------------------------------------------

def test_registry_defaults_seeded_from_profiles():
    reg = TileRegistry()
    for prof in (TPU_V5E, GPU_GENERIC, CPU_INTERPRET):
        g = reg.lookup_op(OP_GEMM, prof.name, jnp.bfloat16)
        assert g.source == "default"
        assert g.config == TileConfig(*prof.gemm_block)
        f = reg.lookup_op(OP_FLASH_ATTENTION, prof.name, jnp.bfloat16)
        assert f.source == "default"
        assert f.config == FlashAttentionConfig(*prof.flash_block)


def test_unknown_hardware_warns_once_and_serves_seeded_defaults(monkeypatch):
    """Satellite bugfix: an unknown hardware name used to escape as a bare
    KeyError from deep inside registry.py; it must fall back to the detected
    profile's seeded defaults with a once-per-process warning."""
    monkeypatch.delenv(hw.HARDWARE_ENV, raising=False)
    monkeypatch.setattr(registry_mod, "_WARNED_UNKNOWN_HARDWARE", set())
    reg = TileRegistry()
    detected = hw.get_profile(hw.detect_hardware())
    with pytest.warns(UserWarning, match="unknown hardware 'knl-7250'"):
        res = reg.lookup("knl-7250", jnp.bfloat16, 64, 64, 64)
    assert res.source == "fallback"
    assert res.config == TileConfig(*detected.gemm_block)
    # flash lookups fall back the same way; the warning fires only once
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        res2 = reg.lookup_op(OP_FLASH_ATTENTION, "knl-7250", jnp.float32)
        reg.lookup("knl-7250", jnp.float32, 8, 8, 8)
    assert res2.config == FlashAttentionConfig(*detected.flash_block)
    assert not [w for w in caught if "unknown hardware" in str(w.message)]


def test_register_profile_gives_new_backend_a_default_tier():
    name = "test-exotic-accel"
    prof = register_profile(hw.HardwareProfile(
        name=name, platform=hw.PLATFORM_GPU,
        peak_flops={"bfloat16": 1e12, "float32": 5e11},
        hbm_bandwidth=100e9, vmem_bytes=1 << 20, ici_link_bandwidth=1e9,
        mxu_dim=16, sublane=2, gemm_block=(16, 32, 32), flash_block=(16, 16)))
    try:
        reg = TileRegistry()
        res = reg.lookup(name, jnp.bfloat16, 128, 128, 128)
        assert res.source == "default"
        assert res.config == TileConfig(16, 32, 32)
    finally:
        hw.HARDWARE.pop(name, None)
    assert prof.default_block("gemm") == (16, 32, 32)


def test_gpu_generic_constraints_admit_a_tuning_space():
    """The gpu-generic profile must define feasible, aligned candidate
    spaces so a GPU runner can tune with zero code changes."""
    gemm_cands = list(TuningSpace().candidates(GPU_GENERIC, jnp.bfloat16))
    assert gemm_cands
    for cfg in gemm_cands:
        assert cfg.fits(GPU_GENERIC, jnp.bfloat16)
        assert cfg.aligned(GPU_GENERIC, jnp.bfloat16)
    flash_cands = list(FlashTuningSpace().candidates(GPU_GENERIC,
                                                     jnp.bfloat16, d=64))
    assert flash_cands
    # and the tuner accepts the profile BY NAME (string), end to end
    res = sweep_gemm(512, 512, 512, dtype=jnp.bfloat16, mode="model",
                     hardware=GPU_GENERIC.name, record=False)
    assert res.hardware == GPU_GENERIC.name
    assert res.points


# ---------------------------------------------------------------------------
# TuningDB isolation across hardware names
# ---------------------------------------------------------------------------

def test_tuning_db_roundtrip_two_hardware_no_cross_contamination(tmp_path):
    def rec(bm):
        return TuningRecord.gemm("bfloat16", 1024, 1024, 1024, bm, bm, bm)

    db_a = TuningDB(TPU_V5E.name)
    db_a.add(rec(512))
    db_b = TuningDB(CPU_INTERPRET.name)
    db_b.add(rec(32))
    path_a = str(tmp_path / f"{TPU_V5E.name}.json")
    path_b = str(tmp_path / f"{CPU_INTERPRET.name}.json")
    db_a.save(path_a)
    db_b.save(path_b)

    from repro.core.tuning_db import load_all
    reg = TileRegistry()
    loaded = load_all(reg, str(tmp_path))
    assert loaded == {path_a: 1, path_b: 1}
    a = reg.lookup(TPU_V5E.name, jnp.bfloat16, 1024, 1024, 1024)
    b = reg.lookup(CPU_INTERPRET.name, jnp.bfloat16, 1024, 1024, 1024)
    assert (a.source, a.config) == ("exact", TileConfig(512, 512, 512))
    assert (b.source, b.config) == ("exact", TileConfig(32, 32, 32))
    # a third backend sees NEITHER: nearest never crosses hardware buckets
    c = reg.lookup(GPU_GENERIC.name, jnp.bfloat16, 1024, 1024, 1024)
    assert c.source == "default"
    assert c.config == TileConfig(*GPU_GENERIC.gemm_block)


def test_legacy_host_cpu_db_reachable_from_cpu_interpret_lookups(tmp_path):
    """A pre-profile tuned/host-cpu.json must keep resolving: entries are
    canonicalized to cpu-interpret on registry write, so lookups under the
    new name (and the alias) both hit them."""
    db = TuningDB("host-cpu")
    db.add(TuningRecord.gemm("float32", 64, 64, 64, 16, 32, 32,
                             source="measure", seconds=1e-4))
    db.save(str(tmp_path / "host-cpu.json"))
    from repro.core.tuning_db import load_all
    reg = TileRegistry()
    load_all(reg, str(tmp_path))
    for name in (CPU_INTERPRET.name, "host-cpu"):
        res = reg.lookup(name, jnp.float32, 64, 64, 64)
        assert (res.source, res.config) == ("exact", TileConfig(16, 32, 32))


def test_committed_cpu_interpret_db_exists_and_loads():
    """Acceptance: tuned/cpu-interpret.json is committed and loads under the
    cpu-interpret profile (kernel ops plus the mesh-keyed decode unroll and
    the paged-KV page size)."""
    path = os.path.join(REPO, "tuned", f"{CPU_INTERPRET.name}.json")
    assert os.path.exists(path), "tuned/cpu-interpret.json must be committed"
    db = TuningDB.from_file(path)
    assert db.hardware == CPU_INTERPRET.name
    assert set(db.ops()) == {"gemm", "flash_attention", "decode_loop",
                             "paged_attn"}
    reg = TileRegistry()
    from repro.core.tuning_db import load_into_registry
    assert load_into_registry(reg, path) == len(db) > 0
    rec = db.records("gemm")[0]
    res = reg.lookup(CPU_INTERPRET.name, rec.dtype, *rec.shape)
    assert res.source == "exact"


# ---------------------------------------------------------------------------
# Engine provenance
# ---------------------------------------------------------------------------

def test_engine_stats_carry_hardware_provenance():
    from repro.configs.catalog import get_config
    from repro.models import build_model
    from repro.serve import Engine, ServeConfig

    cfg = get_config("llama3.2-1b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = Engine(model, params,
                 ServeConfig(max_batch=2, max_len=64,
                             hardware=CPU_INTERPRET.name))
    eng.generate([[1, 2, 3], [4, 5]], max_new_tokens=4)
    st = eng.stats()
    assert st["hardware"] == CPU_INTERPRET.name
    assert st["hardware_platform"] == hw.PLATFORM_CPU_INTERPRET
    assert st["decode_tile_lookups"], "decode tile provenance missing"
    # the legacy alias resolves to the same profile at engine construction
    eng2 = Engine(model, params,
                  ServeConfig(max_batch=2, max_len=64, hardware="host-cpu"))
    assert eng2.hardware == CPU_INTERPRET.name


# ---------------------------------------------------------------------------
# Bench-trend gate (scripts/bench_compare.py)
# ---------------------------------------------------------------------------

def _bench_blob(rows, **extra):
    blob = {"smoke": True, "hardware": CPU_INTERPRET.name,
            "suites": ["gemm_tuning"],
            "rows": [{"name": n, "us_per_call": u, "derived": d}
                     for n, u, d in rows]}
    blob.update(extra)
    return blob


def _run_compare(tmp_path, fresh_rows, base_rows, extra_args=(),
                 tolerances=None):
    base = _bench_blob(base_rows)
    if tolerances is not None:
        base["tolerances"] = tolerances
    bdir = tmp_path / "baselines"
    bdir.mkdir(exist_ok=True)
    name = "BENCH_gemm_tuning__cpu-interpret.json"
    (bdir / name).write_text(json.dumps(base))
    fresh = tmp_path / name
    fresh.write_text(json.dumps(_bench_blob(fresh_rows)))
    proc = subprocess.run(
        [sys.executable, os.path.join(REPO, "scripts", "bench_compare.py"),
         str(fresh), "--baseline-dir", str(bdir), *extra_args],
        capture_output=True, text=True, timeout=120)
    return proc


def test_bench_compare_passes_within_tolerance(tmp_path):
    base = [("gemm_tune/cpu-interpret/bf16/N512/128x128x128", 10.0, 100.0)]
    fresh = [("gemm_tune/cpu-interpret/bf16/N512/256x256x256", 11.0, 80.0)]
    proc = _run_compare(tmp_path, fresh, base)     # -20% < 30% tolerance;
    assert proc.returncode == 0, proc.stdout       # tile label normalized
    assert "PASS" in proc.stdout


def test_bench_compare_fails_on_30pct_regression(tmp_path):
    base = [("gemm_tune/cpu-interpret/bf16/N512/128x128x128", 10.0, 100.0)]
    fresh = [("gemm_tune/cpu-interpret/bf16/N512/128x128x128", 30.0, 60.0)]
    proc = _run_compare(tmp_path, fresh, base)
    assert proc.returncode == 1, proc.stdout
    assert "REGRESSION" in proc.stdout
    # ...unless the per-family tolerance in the baseline JSON allows it
    proc = _run_compare(tmp_path, fresh, base,
                        tolerances={"gemm_tune/": 0.5})
    assert proc.returncode == 0, proc.stdout
    # ...or the CLI-wide override knob is loosened
    proc = _run_compare(tmp_path, fresh, base, extra_args=["--tolerance", ".6"])
    assert proc.returncode == 0, proc.stdout


def test_bench_compare_fails_on_missing_family(tmp_path):
    base = [("serving/llama3.2-1b/prefill_tok_s/B8xP16", 10.0, 100.0)]
    proc = _run_compare(tmp_path, [], base)
    assert proc.returncode == 1
    assert "missing from fresh run" in proc.stdout


def test_bench_compare_zero_baseline_warns_instead_of_silently_passing(tmp_path):
    """Satellite bugfix: a 0.0 baseline used to skip the comparison without
    a word.  It must now warn explicitly (and stay neutral — a zero cannot
    anchor a relative gate)."""
    base = [("serving/llama3.2-1b/prefill_tok_s/B8xP16", 10.0, 0.0)]
    fresh = [("serving/llama3.2-1b/prefill_tok_s/B8xP16", 10.0, 0.0)]
    proc = _run_compare(tmp_path, fresh, base)
    assert proc.returncode == 0, proc.stdout
    assert "warn" in proc.stdout and "zero baseline" in proc.stdout


def test_bench_compare_fails_when_nonzero_family_drops_to_zero(tmp_path):
    """A previously-nonzero family reporting 0.0 is a dead metric — fail
    regardless of how loose the family's tolerance is."""
    base = [("serving/llama3.2-1b/prefill_tok_s/B8xP16", 10.0, 100.0)]
    fresh = [("serving/llama3.2-1b/prefill_tok_s/B8xP16", 10.0, 0.0)]
    proc = _run_compare(tmp_path, fresh, base,
                        tolerances={"serving/": 0.99})
    assert proc.returncode == 1, proc.stdout
    assert "went dead" in proc.stdout


SPEEDUP_FAMILY = "serving/llama3.2-1b/decode_speedup_fused_vs_sync"


def test_bench_compare_require_improvement_gate(tmp_path):
    """Absolute gate for ratio metrics: >= 1.0 means the fused path wins,
    whatever the committed baseline says — a blessed-in regression cannot
    silently return."""
    winning = [(f"{SPEEDUP_FAMILY}-1.07x", 0.0, 1.07)]
    losing = [(f"{SPEEDUP_FAMILY}-0.54x", 0.0, 0.54)]
    # pass: family present and >= 1.0 (the -1.07x suffix normalizes away)
    proc = _run_compare(tmp_path, winning, winning,
                        extra_args=["--require-improvement", SPEEDUP_FAMILY])
    assert proc.returncode == 0, proc.stdout
    assert "required improvement holds" in proc.stdout
    # fail: present but < 1.0 — even though the relative trend gate passes
    proc = _run_compare(tmp_path, losing, losing,
                        extra_args=["--require-improvement", SPEEDUP_FAMILY])
    assert proc.returncode == 1, proc.stdout
    assert "REQUIRED IMPROVEMENT FAILED" in proc.stdout
    # fail: family missing entirely
    other = [("serving/llama3.2-1b/prefill_tok_s/B8xP16", 1.0, 10.0)]
    proc = _run_compare(tmp_path, other, other,
                        extra_args=["--require-improvement", SPEEDUP_FAMILY])
    assert proc.returncode == 1, proc.stdout
    assert "family missing" in proc.stdout


def test_bench_compare_refuses_to_bless_failing_requirement(tmp_path):
    """--write-baseline must not capture a file that fails the absolute
    gate: losing runs cannot become the new normal."""
    losing = [(f"{SPEEDUP_FAMILY}-0.54x", 0.0, 0.54)]
    name = "BENCH_gemm_tuning__cpu-interpret.json"
    bdir = tmp_path / "baselines"
    proc = _run_compare(tmp_path, losing, losing,
                        extra_args=["--require-improvement", SPEEDUP_FAMILY,
                                    "--write-baseline"])
    # _run_compare pre-writes the baseline file; blessing would REWRITE it
    # with the fresh (losing) rows — verify it still holds the old blob
    assert proc.returncode == 1, proc.stdout
    assert "refusing to bless" in proc.stdout
    base = json.loads((bdir / name).read_text())
    assert base["rows"][0]["derived"] == 0.54   # pre-written, not re-blessed
    assert "tolerances" not in base             # bless would have added them


def test_committed_bench_baselines_exist():
    bdir = os.path.join(REPO, "benchmarks", "baselines")
    for suite in ("gemm_tuning", "attention_tuning", "serving"):
        path = os.path.join(bdir,
                            f"BENCH_{suite}__{CPU_INTERPRET.name}.json")
        assert os.path.exists(path), f"missing committed baseline {path}"
        blob = json.load(open(path))
        assert blob["hardware"] == CPU_INTERPRET.name
        assert blob["rows"] and blob["tolerances"]
