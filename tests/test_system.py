"""End-to-end system behaviour: training convergence, checkpoint-restart
bitwise continuation, serving consistency, grad-accum equivalence."""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer
from repro.configs.catalog import ARCHITECTURES
from repro.data import DataConfig, TokenPipeline
from repro.models import build_model
from repro.optim import AdamW
from repro.serve import Engine, ServeConfig
from repro.train import (Trainer, TrainerConfig, init_train_state,
                         make_train_step)


def _tiny_setup(arch="llama3.2-1b", lr=3e-3, **cfg_overrides):
    cfg = ARCHITECTURES[arch].reduced()
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    model = build_model(cfg)
    opt = AdamW(learning_rate=lr)
    pipe = TokenPipeline(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                    global_batch=8))
    return cfg, model, opt, pipe


def test_training_loss_decreases():
    cfg, model, opt, pipe = _tiny_setup()
    state = init_train_state(model, opt, jax.random.PRNGKey(0))
    step = jax.jit(make_train_step(model, opt), donate_argnums=(0,))
    losses = []
    for i in range(40):
        state, m = step(state, pipe(i))
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.5


def test_checkpoint_restart_bitwise_identical(tmp_path):
    """Fault-tolerance core property: kill at step 10, restore, continue to
    20 -> identical params as the uninterrupted run (deterministic data)."""
    cfg, model, opt, pipe = _tiny_setup()
    step = jax.jit(make_train_step(model, opt))

    state = init_train_state(model, opt, jax.random.PRNGKey(0))
    for i in range(10):
        state, _ = step(state, pipe(i))
    ck = Checkpointer(str(tmp_path))
    ck.save(10, state)
    for i in range(10, 20):
        state, _ = step(state, pipe(i))
    uninterrupted = state

    # simulated failure + restart
    template = jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), uninterrupted)
    restored = ck.restore(10, template)
    for i in range(10, 20):
        restored, _ = step(restored, pipe(i))

    flat_a = jax.tree_util.tree_leaves(uninterrupted.params)
    flat_b = jax.tree_util.tree_leaves(restored.params)
    for a, b in zip(flat_a, flat_b):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_grad_accumulation_equivalent():
    """microbatches=4 must match a single full-batch step (within f32 eps)."""
    cfg, model, opt, pipe = _tiny_setup(lr=1e-3)
    batch = pipe(0)
    s1 = init_train_state(model, opt, jax.random.PRNGKey(0))
    s2 = init_train_state(model, opt, jax.random.PRNGKey(0))
    step1 = jax.jit(make_train_step(model, opt, microbatches=1))
    step4 = jax.jit(make_train_step(model, opt, microbatches=4))
    s1, m1 = step1(s1, batch)
    s2, m2 = step4(s2, batch)
    np.testing.assert_allclose(float(m1["loss"]), float(m2["loss"]),
                               rtol=1e-5)
    for a, b in zip(jax.tree_util.tree_leaves(s1.params),
                    jax.tree_util.tree_leaves(s2.params)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=1e-4, atol=1e-5)


def test_compression_training_still_converges():
    cfg, model, opt, pipe = _tiny_setup()
    state = init_train_state(model, opt, jax.random.PRNGKey(0),
                             use_compression=True)
    step = jax.jit(make_train_step(model, opt, use_compression=True),
                   donate_argnums=(0,))
    losses = []
    for i in range(40):
        state, m = step(state, pipe(i))
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.4


def test_trainer_loop_with_checkpointing(tmp_path):
    cfg, model, opt, pipe = _tiny_setup()
    tcfg = TrainerConfig(total_steps=6, log_every=2, checkpoint_every=3)
    trainer = Trainer(model, opt, pipe, tcfg,
                      checkpointer=Checkpointer(str(tmp_path)))
    state = init_train_state(model, opt, jax.random.PRNGKey(0))
    state, history = trainer.run(state)
    assert int(state.step) == 6
    assert len(history) == 3
    assert trainer.checkpointer.latest_step() == 6


def test_serving_matches_forward_argmax():
    """Engine greedy generation == argmax over teacher-forced forward."""
    cfg, model, opt, _ = _tiny_setup()
    params = model.init(jax.random.PRNGKey(1))
    eng = Engine(model, params, ServeConfig(max_batch=2, max_len=64))
    prompts = [[5, 9, 2, 7], [1, 3, 3, 7]]
    outs = eng.generate(prompts, max_new_tokens=5)
    # replay: teacher-forced forward over prompt+generated must re-produce
    # each generated token as the argmax at its position
    for p, o in zip(prompts, outs):
        seq = p + o
        logits, _ = model.forward(
            params, {"tokens": jnp.asarray([seq], jnp.int32)})
        for j in range(len(o)):
            pos = len(p) - 1 + j
            assert int(jnp.argmax(logits[0, pos])) == seq[len(p) + j]


def test_serving_ssm_family():
    cfg, model, opt, _ = _tiny_setup(arch="mamba2-130m")
    params = model.init(jax.random.PRNGKey(1))
    eng = Engine(model, params, ServeConfig(max_batch=2, max_len=64))
    outs = eng.generate([[5, 9, 2], [1, 3, 3]], max_new_tokens=4)
    assert all(len(o) == 4 for o in outs)
    seq = [5, 9, 2] + outs[0]
    logits, _ = model.forward(params, {"tokens": jnp.asarray([seq], jnp.int32)})
    assert int(jnp.argmax(logits[0, 2])) == outs[0][0]
