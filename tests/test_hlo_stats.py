"""Calibration tests for the HLO analyzer (the roofline's measurement layer).

cost_analysis() counts while bodies once (verified here); analyze_hlo must
recover exact trip-count-weighted dot flops and detect collectives.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.hlo_stats import analyze_hlo


def _compile(fn, *specs):
    return jax.jit(fn).lower(*specs).compile()


def _sds(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def test_plain_matmul_flops_exact():
    m = k = n = 128
    c = _compile(lambda a, b: a @ b, _sds((m, k)), _sds((k, n)))
    s = analyze_hlo(c.as_text())
    assert s.flops == 2 * m * k * n
    assert s.dot_count == 1


def test_scan_trip_count_recovered():
    def g(a, ws):
        def body(x, w):
            return jnp.tanh(x @ w), None
        return jax.lax.scan(body, a, ws)[0]
    c = _compile(g, _sds((64, 64)), _sds((10, 64, 64)))
    s = analyze_hlo(c.as_text())
    assert s.flops == 10 * 2 * 64 ** 3
    assert 10 in s.while_trips
    # the raw cost_analysis undercount that motivates the analyzer:
    ca = c.cost_analysis()
    ca = ca[0] if isinstance(ca, (list, tuple)) else ca
    assert ca["flops"] < s.flops


def test_nested_scan_multiplies():
    def h(a, ws):
        def outer(x, w3):
            def inner(y, w):
                return y @ w, None
            return jax.lax.scan(inner, x, w3)[0], None
        return jax.lax.scan(outer, a, ws)[0]
    c = _compile(h, _sds((32, 32)), _sds((5, 3, 32, 32)))
    s = analyze_hlo(c.as_text())
    assert s.flops == 15 * 2 * 32 ** 3


def test_batched_dot_flops():
    c = _compile(lambda a, b: jnp.einsum("bij,bjk->bik", a, b),
                 _sds((4, 16, 32)), _sds((4, 32, 8)))
    s = analyze_hlo(c.as_text())
    assert s.flops == 4 * 2 * 16 * 32 * 8


def test_traffic_bytes_plausible_for_matmul():
    m = k = n = 256
    c = _compile(lambda a, b: a @ b, _sds((m, k)), _sds((k, n)))
    s = analyze_hlo(c.as_text())
    minimal = (m * k + k * n + m * n) * 4
    assert minimal <= s.traffic_bytes <= 3 * minimal


def test_remat_duplication_visible():
    """jax.checkpoint recompute shows up as extra dot flops vs no-remat —
    exactly the MODEL_FLOPS/HLO_FLOPs waste signal the roofline tracks."""
    n_layers = 10

    def make(remat):
        def loss(x, ws):
            def body(h, w):
                return jnp.tanh(h @ w), None
            f = jax.checkpoint(body) if remat else body
            out, _ = jax.lax.scan(f, x, ws)
            return jnp.sum(out)
        return jax.grad(loss)

    base = 2 * 64 ** 3
    specs = (_sds((64, 64)), _sds((n_layers, 64, 64)))
    plain = analyze_hlo(_compile(make(False), *specs).as_text())
    remat = analyze_hlo(_compile(make(True), *specs).as_text())
    # remat backward recomputes the fwd dot every layer
    assert remat.flops >= plain.flops + (n_layers - 1) * base
