"""Serving-engine coverage for the stub-frontend families (VLM, audio) and
temperature sampling."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.catalog import ARCHITECTURES
from repro.models import build_model
from repro.serve import Engine, ServeConfig


def _engine(arch, temperature=0.0):
    cfg = ARCHITECTURES[arch].reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    eng = Engine(model, params, ServeConfig(max_batch=2, max_len=64,
                                            temperature=temperature))
    extra = {k: 0.02 * jax.random.normal(jax.random.PRNGKey(2), sds.shape
                                         ).astype(sds.dtype)
             for k, sds in model.extra_inputs(2).items()}
    return cfg, model, params, eng, extra


def test_vlm_generation_conditions_on_image():
    cfg, model, params, eng, extra = _engine("llama-3.2-vision-11b")
    prompts = [[1, 2, 3], [4, 5, 6]]
    out_a = eng.generate(prompts, 4, extra_inputs=extra)
    # different image embeddings must be able to change the generation
    extra_b = {k: v + 1.0 for k, v in extra.items()}
    out_b = eng.generate(prompts, 4, extra_inputs=extra_b)
    assert all(len(o) == 4 for o in out_a + out_b)
    # not asserting inequality per-token (tiny random model), but outputs
    # must be valid token ids
    for o in out_a + out_b:
        assert all(0 <= t < cfg.vocab_size for t in o)


def test_whisper_generation_runs():
    cfg, model, params, eng, extra = _engine("whisper-large-v3")
    outs = eng.generate([[7, 8], [9, 10]], 5, extra_inputs=extra)
    assert all(len(o) == 5 for o in outs)


def test_temperature_sampling_varies():
    cfg, model, params, eng, extra = _engine("llama3.2-1b", temperature=2.0)
    outs1 = eng.generate([[1, 2, 3, 4]], 12)
    # same seed -> deterministic even with temperature
    eng2 = Engine(model, params, ServeConfig(max_batch=2, temperature=2.0))
    outs2 = eng2.generate([[1, 2, 3, 4]], 12)
    assert outs1 == outs2


def test_eos_stops_early():
    cfg = ARCHITECTURES["llama3.2-1b"].reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    # find the greedy first token, then set it as EOS: generation len == 1
    eng0 = Engine(model, params, ServeConfig(max_batch=1))
    first = eng0.generate([[3, 1, 4]], 1)[0][0]
    eng = Engine(model, params, ServeConfig(max_batch=1, eos_token=first))
    outs = eng.generate([[3, 1, 4]], 8)
    assert outs[0][0] == first and len(outs[0]) == 1
